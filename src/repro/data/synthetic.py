"""Synthetic corpora with learned-sparse-retrieval statistics.

MS MARCO / BEIR and trained SPLADE weights are not available offline, so
benchmarks run on corpora that mimic the relevant statistics of learned
sparse representations (see paper §2/§4):

  * Zipfian term frequencies over a WordPiece-sized vocab;
  * ~tens of nonzero terms per passage (MS MARCO mean 67.5 WordPiece
    tokens), more per expanded query (SPLADE Dev mean >23);
  * nonnegative, roughly log-normal impact weights;
  * topical structure: documents are drawn from latent topics so that
    k-means clustering finds real cluster structure (otherwise cluster
    skipping would be trivially useless and the paper's effect invisible);
  * queries are drawn from the same topics with extra expansion noise, and
    their relevant documents are the in-topic ones — giving a synthetic
    qrels for MRR/recall-style metrics.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.types import QueryBatch, SparseDocs


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    n_docs: int = 4096
    vocab: int = 2048
    n_topics: int = 64
    doc_terms: int = 48          # mean nnz per document
    t_pad: int = 64
    query_terms: int = 16        # mean nnz per query (SPLADE-expanded)
    q_pad: int = 24
    zipf_a: float = 1.2
    topic_sharpness: float = 0.7  # fraction of terms drawn from the topic
    seed: int = 0


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


def make_corpus(spec: CorpusSpec) -> tuple[SparseDocs, np.ndarray]:
    """Returns (docs, doc_topic (n_docs,))."""
    rng = np.random.default_rng(spec.seed)
    base_p = _zipf_probs(spec.vocab, spec.zipf_a)
    # per-topic term distributions: re-weight a random subset of the vocab
    topic_boost = np.ones((spec.n_topics, spec.vocab))
    topic_size = max(8, spec.vocab // spec.n_topics)
    for z in range(spec.n_topics):
        terms = rng.choice(spec.vocab, topic_size, replace=False)
        topic_boost[z, terms] *= 50.0
    topic_p = topic_boost * base_p[None, :]
    topic_p /= topic_p.sum(-1, keepdims=True)

    doc_topic = rng.integers(0, spec.n_topics, spec.n_docs)
    tids = np.full((spec.n_docs, spec.t_pad), -1, np.int32)
    tw = np.zeros((spec.n_docs, spec.t_pad), np.float32)
    mask = np.zeros((spec.n_docs, spec.t_pad), bool)
    for d in range(spec.n_docs):
        nnz = int(np.clip(rng.poisson(spec.doc_terms), 4, spec.t_pad))
        n_topic = int(round(nnz * spec.topic_sharpness))
        t1 = rng.choice(spec.vocab, n_topic, replace=False,
                        p=topic_p[doc_topic[d]])
        t2 = rng.choice(spec.vocab, nnz - n_topic, replace=False, p=base_p)
        terms = np.unique(np.concatenate([t1, t2]))[:nnz]
        w = rng.lognormal(mean=0.0, sigma=0.6, size=len(terms)).astype(
            np.float32)
        tids[d, : len(terms)] = terms
        tw[d, : len(terms)] = w
        mask[d, : len(terms)] = True

    docs = SparseDocs(tids=jnp.asarray(tids), tw=jnp.asarray(tw),
                      mask=jnp.asarray(mask), vocab=spec.vocab)
    return docs, doc_topic


def make_queries(spec: CorpusSpec, n_queries: int,
                 doc_topic: np.ndarray,
                 seed: int = 1) -> tuple[QueryBatch, np.ndarray]:
    """Returns (queries, qrels) where qrels[q] is the query's topic; the
    relevant set of query q is ``{d : doc_topic[d] == qrels[q]}``."""
    rng = np.random.default_rng(seed)
    base_p = _zipf_probs(spec.vocab, spec.zipf_a)
    topic_boost = np.ones((spec.n_topics, spec.vocab))
    topic_size = max(8, spec.vocab // spec.n_topics)
    rng_topics = np.random.default_rng(spec.seed)   # same topics as corpus
    topic_terms = []
    for z in range(spec.n_topics):
        terms = rng_topics.choice(spec.vocab, topic_size, replace=False)
        topic_terms.append(terms)
        topic_boost[z, terms] *= 50.0

    q_topic = rng.integers(0, spec.n_topics, n_queries)
    tids = np.full((n_queries, spec.q_pad), -1, np.int32)
    tw = np.zeros((n_queries, spec.q_pad), np.float32)
    mask = np.zeros((n_queries, spec.q_pad), bool)
    for q in range(n_queries):
        nnz = int(np.clip(rng.poisson(spec.query_terms), 2, spec.q_pad))
        n_topic = max(1, int(round(nnz * 0.8)))
        t1 = rng.choice(topic_terms[q_topic[q]],
                        min(n_topic, len(topic_terms[q_topic[q]])),
                        replace=False)
        t2 = rng.choice(spec.vocab, max(0, nnz - len(t1)), replace=False,
                        p=base_p)
        terms = np.unique(np.concatenate([t1, t2]))[:nnz]
        w = rng.lognormal(mean=0.0, sigma=0.5, size=len(terms)).astype(
            np.float32)
        tids[q, : len(terms)] = terms
        tw[q, : len(terms)] = w
        mask[q, : len(terms)] = True

    queries = QueryBatch(tids=jnp.asarray(tids), tw=jnp.asarray(tw),
                         mask=jnp.asarray(mask), vocab=spec.vocab)
    return queries, q_topic
