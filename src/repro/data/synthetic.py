"""Synthetic corpora with learned-sparse-retrieval statistics.

MS MARCO / BEIR and trained SPLADE weights are not available offline, so
benchmarks run on corpora that mimic the relevant statistics of learned
sparse representations (see paper §2/§4):

  * Zipfian term frequencies over a WordPiece-sized vocab;
  * ~tens of nonzero terms per passage (MS MARCO mean 67.5 WordPiece
    tokens), more per expanded query (SPLADE Dev mean >23);
  * nonnegative, roughly log-normal impact weights;
  * topical structure: documents are drawn from latent topics so that
    k-means clustering finds real cluster structure (otherwise cluster
    skipping would be trivially useless and the paper's effect invisible);
  * queries are drawn from the same topics with extra expansion noise, and
    their relevant documents are the in-topic ones — giving a synthetic
    qrels for MRR/recall-style metrics.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.types import QueryBatch, SparseDocs


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    n_docs: int = 4096
    vocab: int = 2048
    n_topics: int = 64
    doc_terms: int = 48          # mean nnz per document
    t_pad: int = 64
    query_terms: int = 16        # mean nnz per query (SPLADE-expanded)
    q_pad: int = 24
    zipf_a: float = 1.2
    topic_sharpness: float = 0.7  # fraction of terms drawn from the topic
    # within-cluster heterogeneity: lognormal sigma of a per-document
    # quality multiplier. At 0 (default) no rng draw is consumed — every
    # seeded fixture/golden built before the knob existed is bit-exact.
    # Positive values spread document magnitudes *inside* a topic, so
    # random segmentation yields discriminating segment maxima (segment
    # pruning fires at n_seg=4) and clusters differ enough that coarse
    # superblock bounds discriminate too (ROADMAP carry-over; pinned by
    # tests/test_rank_safety_property.py::test_heterogeneity_makes_
    # pruning_fire_at_defaults).
    doc_quality_sigma: float = 0.0
    # upper clip on the per-document quality multiplier (0 = unclipped,
    # default, bit-exact historical stream). Real learned-sparse impact
    # scores are bounded (uint8-quantized in production indexes); an
    # unclipped lognormal at corpus scale produces "whale" documents
    # whose background terms put quality-scaled maxima into otherwise
    # unrelated clusters' bound tables, which no sound coarse bound can
    # prune. Clipping bounds that tail while keeping within-topic
    # heterogeneity (docs/perf.md §superblock).
    doc_quality_clip: float = 0.0
    # fraction of query terms drawn from the query's topic (the rest are
    # zipf-background "expansion noise"). The 0.8 default reproduces the
    # historical stream bit-exactly. Background query terms are zipf-head
    # terms present in *every* cluster, so they put a floor under every
    # cluster/superblock bound-sum — 1.0 models a fully-topical expansion
    # (SPLADE-style semantically related terms), the regime where coarse
    # bound pruning can discriminate (docs/perf.md §superblock).
    query_sharpness: float = 0.8
    # weight multiplier on a document's *background* (non-topical) terms.
    # Learned sparse models concentrate impact mass on a passage's central
    # terms; expansion/background terms carry much smaller weights (paper
    # §2). At the 1.0 default background terms draw from the same
    # lognormal as topical ones (historical stream, bit-exact); < 1.0
    # shrinks them, which tightens cluster/superblock max tables on
    # off-topic terms — the statistic coarse bound pruning keys on.
    doc_bg_weight: float = 1.0
    # topic vocabularies: False (default) draws each topic's term set
    # independently from the vocab, so topics overlap (expected ~1 other
    # topic per term) and a query's terms are first-class topical terms
    # of other topics too. True assigns *strided* disjoint term sets
    # (topic z gets ranks z, z+n_topics, ...), giving every topic an
    # identical zipf popularity profile with zero cross-topic overlap —
    # the domain-separated regime where coarse bounds can tell an
    # off-topic superblock from an on-topic one (docs/perf.md
    # §superblock). Default is bit-exact with the historical stream.
    disjoint_topics: bool = False
    # multiplier on a topic's term probabilities when drawing a document's
    # topical terms. At the 50.0 default (historical stream, bit-exact) a
    # topic's ~vocab/n_topics terms carry only ~half the boosted draw
    # mass — the other half of every "topical" draw is a full-weight
    # zipf-background term, which leaks query terms into off-topic
    # clusters' bound tables. Raising it (>= ~1000) makes topical draws
    # actually topical, the regime where coarse bounds separate on-topic
    # from off-topic superblocks (docs/perf.md §superblock).
    topic_boost: float = 50.0
    # query *topic popularity* skew: 0 (default, bit-exact stream) draws
    # query topics uniformly; > 0 draws them zipf(a)-skewed over a
    # seed-derived permutation of the topics (so popularity is decoupled
    # from topic id and hence from cluster adjacency). Production query
    # workloads are popularity-skewed; a batch of 64 uniform-topic
    # queries touches nearly every topic, and the batched engine's
    # shared walk pays the *union* of the batch's admissions — workload
    # locality is what makes batch-level level-0 pruning bite
    # (docs/perf.md §superblock).
    query_topic_zipf_a: float = 0.0
    seed: int = 0


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


def make_corpus(spec: CorpusSpec) -> tuple[SparseDocs, np.ndarray]:
    """Returns (docs, doc_topic (n_docs,))."""
    rng = np.random.default_rng(spec.seed)
    base_p = _zipf_probs(spec.vocab, spec.zipf_a)
    # per-topic term distributions: re-weight a random subset of the vocab
    topic_boost = np.ones((spec.n_topics, spec.vocab))
    topic_size = max(8, spec.vocab // spec.n_topics)
    for z in range(spec.n_topics):
        if spec.disjoint_topics:
            terms = np.arange(z, spec.vocab, spec.n_topics)[:topic_size]
        else:
            terms = rng.choice(spec.vocab, topic_size, replace=False)
        topic_boost[z, terms] *= spec.topic_boost
    topic_p = topic_boost * base_p[None, :]
    topic_p /= topic_p.sum(-1, keepdims=True)

    doc_topic = rng.integers(0, spec.n_topics, spec.n_docs)
    tids = np.full((spec.n_docs, spec.t_pad), -1, np.int32)
    tw = np.zeros((spec.n_docs, spec.t_pad), np.float32)
    mask = np.zeros((spec.n_docs, spec.t_pad), bool)
    for d in range(spec.n_docs):
        nnz = int(np.clip(rng.poisson(spec.doc_terms), 4, spec.t_pad))
        n_topic = int(round(nnz * spec.topic_sharpness))
        t1 = rng.choice(spec.vocab, n_topic, replace=False,
                        p=topic_p[doc_topic[d]])
        t2 = rng.choice(spec.vocab, nnz - n_topic, replace=False, p=base_p)
        terms = np.unique(np.concatenate([t1, t2]))[:nnz]
        w = rng.lognormal(mean=0.0, sigma=0.6, size=len(terms)).astype(
            np.float32)
        if spec.doc_quality_sigma > 0:
            # drawn only when enabled: the default stream is untouched
            q_mult = rng.lognormal(0.0, spec.doc_quality_sigma)
            if spec.doc_quality_clip > 0:
                q_mult = min(q_mult, spec.doc_quality_clip)
            w *= np.float32(q_mult)
        if spec.doc_bg_weight != 1.0:
            # no rng draw: the default stream is untouched
            w = np.where(np.isin(terms, t1), w,
                         w * np.float32(spec.doc_bg_weight)).astype(
                             np.float32)
        tids[d, : len(terms)] = terms
        tw[d, : len(terms)] = w
        mask[d, : len(terms)] = True

    docs = SparseDocs(tids=jnp.asarray(tids), tw=jnp.asarray(tw),
                      mask=jnp.asarray(mask), vocab=spec.vocab)
    return docs, doc_topic


def make_queries(spec: CorpusSpec, n_queries: int,
                 doc_topic: np.ndarray,
                 seed: int = 1) -> tuple[QueryBatch, np.ndarray]:
    """Returns (queries, qrels) where qrels[q] is the query's topic; the
    relevant set of query q is ``{d : doc_topic[d] == qrels[q]}``."""
    rng = np.random.default_rng(seed)
    base_p = _zipf_probs(spec.vocab, spec.zipf_a)
    topic_boost = np.ones((spec.n_topics, spec.vocab))
    topic_size = max(8, spec.vocab // spec.n_topics)
    rng_topics = np.random.default_rng(spec.seed)   # same topics as corpus
    topic_terms = []
    for z in range(spec.n_topics):
        if spec.disjoint_topics:
            terms = np.arange(z, spec.vocab, spec.n_topics)[:topic_size]
        else:
            terms = rng_topics.choice(spec.vocab, topic_size, replace=False)
        topic_terms.append(terms)
        topic_boost[z, terms] *= spec.topic_boost

    if spec.query_topic_zipf_a > 0:
        pz = 1.0 / np.arange(1, spec.n_topics + 1) ** spec.query_topic_zipf_a
        pz /= pz.sum()
        perm = rng.permutation(spec.n_topics)
        q_topic = perm[rng.choice(spec.n_topics, n_queries, p=pz)]
    else:
        q_topic = rng.integers(0, spec.n_topics, n_queries)
    tids = np.full((n_queries, spec.q_pad), -1, np.int32)
    tw = np.zeros((n_queries, spec.q_pad), np.float32)
    mask = np.zeros((n_queries, spec.q_pad), bool)
    for q in range(n_queries):
        nnz = int(np.clip(rng.poisson(spec.query_terms), 2, spec.q_pad))
        n_topic = max(1, int(round(nnz * spec.query_sharpness)))
        t1 = rng.choice(topic_terms[q_topic[q]],
                        min(n_topic, len(topic_terms[q_topic[q]])),
                        replace=False)
        t2 = rng.choice(spec.vocab, max(0, nnz - len(t1)), replace=False,
                        p=base_p)
        terms = np.unique(np.concatenate([t1, t2]))[:nnz]
        w = rng.lognormal(mean=0.0, sigma=0.5, size=len(terms)).astype(
            np.float32)
        tids[q, : len(terms)] = terms
        tw[q, : len(terms)] = w
        mask[q, : len(terms)] = True

    queries = QueryBatch(tids=jnp.asarray(tids), tw=jnp.asarray(tw),
                         mask=jnp.asarray(mask), vocab=spec.vocab)
    return queries, q_topic
