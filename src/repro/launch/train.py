"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real fault-tolerant training job for any assigned architecture on
the local device set. ``--preset smoke`` (default) uses the reduced config
so the job runs on one CPU; ``--preset full`` uses the production config
(expects real accelerators). ``--devices N`` forces N host devices to
exercise the sharded path end-to-end on CPU.

On a multi-host TPU deployment the entry point is identical — jax picks
up the real topology; the mesh is carved from whatever is available.
"""

import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = native)")
    ap.add_argument("--grad-compression", action="store_true",
                    help="int8+EF compression on the 'pod' axis")
    return ap.parse_args()


def main() -> None:
    args = _parse()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp

    from repro.configs import arch_kind, get_arch
    from repro.data import pipeline as pl
    from repro.distributed import sharding as sh
    from repro.launch.cells import _shardings
    from repro.training import optimizer as opt_lib
    from repro.training.train_loop import TrainConfig, fit

    kind = arch_kind(args.arch)
    mod = get_arch(args.arch)
    cfg = mod.smoke_config() if args.preset == "smoke" else mod.config()

    n_dev = jax.device_count()
    mesh = None
    if n_dev > 1:
        # square-ish (data, model) mesh from whatever devices exist
        data = 1
        while data * data <= n_dev and n_dev % (data * 2) == 0:
            data *= 2
        mesh = jax.make_mesh((n_dev // (n_dev // data), n_dev // data)
                             if False else (data, n_dev // data),
                             ("data", "model"))
        print(f"[train] mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    if kind == "lm":
        from repro.models import transformer as tf
        rules = sh.lm_rules(mesh, training=True) if mesh else None
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: tf.loss_fn(p, b, cfg)
        spec = pl.LMDataSpec(cfg.vocab, args.seq + 1, args.batch)
        data_fn = lambda s: {k: v[:, : args.seq]
                             for k, v in pl.lm_batch(spec, s).items()}
    elif kind == "gnn":
        from repro.models import gnn
        rules = sh.gnn_rules(mesh) if mesh else None
        params = gnn.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: gnn.loss_fn(p, b, cfg)
        gspec = pl.GraphSpec(256, 1024, cfg.node_in, cfg.edge_in,
                             cfg.node_out)
        data_fn = lambda s: pl.random_graph(gspec, s)
    elif kind == "recsys":
        from repro.models import recsys as rs
        rules = sh.recsys_rules(mesh) if mesh else None
        fns = {"dlrm-mlperf": (rs.dlrm_init, rs.dlrm_loss, pl.dlrm_batch),
               "din": (rs.din_init, rs.din_loss, pl.din_batch),
               "deepfm": (rs.deepfm_init, rs.deepfm_loss, pl.deepfm_batch),
               "bert4rec": (rs.bert4rec_init, rs.bert4rec_loss,
                            pl.bert4rec_batch)}
        init_fn, lf, batch_fn = fns[args.arch]
        params = init_fn(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: lf(p, b, cfg)
        data_fn = lambda s: batch_fn(cfg, args.batch, s)
    else:
        print(f"[train] arch kind {kind!r} has no train step "
              f"(use repro.launch.serve)", file=sys.stderr)
        raise SystemExit(2)

    optimizer = opt_lib.adamw(
        opt_lib.cosine_schedule(3e-4, warmup=max(1, args.steps // 10),
                                total=args.steps))
    tcfg = TrainConfig(steps=args.steps,
                       log_every=max(1, args.steps // 10),
                       checkpoint_every=max(5, args.steps // 3),
                       grad_compression=args.grad_compression)

    ctx = sh.use_rules(rules) if rules else None
    if mesh is not None:
        with mesh, ctx:
            params, history = fit(params=params, optimizer=optimizer,
                                  loss_fn=loss_fn, data_fn=data_fn,
                                  cfg=tcfg, ckpt_dir=args.ckpt_dir)
    else:
        params, history = fit(params=params, optimizer=optimizer,
                              loss_fn=loss_fn, data_fn=data_fn,
                              cfg=tcfg, ckpt_dir=args.ckpt_dir)
    print(f"[train] done: loss {history[0]['loss']:.4f} -> "
          f"{history[-1]['loss']:.4f} over {args.steps} steps")


if __name__ == "__main__":
    main()
