"""Serving launcher: ``python -m repro.launch.serve [...]``.

Builds an ASC cluster-skipping index over a synthetic corpus (or cold
starts from a saved one via --load-dir) and serves query batches through
the RetrievalEngine, printing latency percentiles and work counters.

Lifecycle options:
  --churn N       between batches, delete+insert N docs through the
                  IndexWriter and publish a new epoch; the engine serves
                  from the SnapshotPublisher, pinning one epoch per batch.
  --budget-ms T   adaptive latency target: the engine's AdaptiveBudget
                  feedback loop retargets the cluster budget per batch
                  (traced scalar — no recompiles).
  --save-dir D    persist the final index (versioned npz shards).
  --load-dir D    cold-start from a persisted index instead of building.

With ``--devices N`` the index is sharded over a forced host mesh and
served through the shard_map selective-search path — the same code that
runs on the production (pod, data, model) mesh.
"""

import argparse
import os


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=6000)
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--clusters", type=int, default=64)
    ap.add_argument("--segments", type=int, default=8)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--mu", type=float, default=0.9)
    ap.add_argument("--eta", type=float, default=1.0)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--budget-ms", type=float, default=0.0,
                    help="latency target (0 = unbudgeted)")
    ap.add_argument("--churn", type=int, default=0,
                    help="docs deleted+inserted between batches")
    ap.add_argument("--save-dir", type=str, default="")
    ap.add_argument("--load-dir", type=str, default="")
    ap.add_argument("--devices", type=int, default=0)
    return ap.parse_args()


def main() -> None:
    args = _parse()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.clustering import (balanced_assign,
                                       dense_rep_projection, lloyd_kmeans)
    from repro.core.index import build_index
    from repro.core.search import SearchConfig, retrieve
    from repro.data.synthetic import CorpusSpec, make_corpus, make_queries
    from repro.lifecycle import IndexWriter, load_index, save_index
    from repro.serving.engine import (AdaptiveBudget, RetrievalEngine,
                                      distributed_retrieve,
                                      index_shard_specs)

    spec = CorpusSpec(n_docs=args.n_docs, vocab=args.vocab,
                      n_topics=max(8, args.clusters // 2))
    docs, doc_topic = make_corpus(spec)
    if args.load_dir:
        index, manifest = load_index(args.load_dir)
        print(f"[serve] cold start from {args.load_dir} "
              f"(epoch {manifest['epoch']}, v{manifest['format_version']})")
        if index.vocab != spec.vocab:
            raise SystemExit(
                f"[serve] queries are generated over --vocab {spec.vocab} "
                f"but the loaded index covers vocab {index.vocab}; pass a "
                f"matching --vocab with --load-dir")
    else:
        rep = dense_rep_projection(docs, dim=96)
        centers, _ = lloyd_kmeans(jax.random.PRNGKey(0), rep,
                                  k=args.clusters, iters=8)
        d_pad = int(2.0 * args.n_docs / args.clusters)
        assign = balanced_assign(rep, centers, capacity=d_pad)
        index = build_index(docs, np.asarray(assign), m=args.clusters,
                            n_seg=args.segments, d_pad=d_pad)
    print(f"[serve] index: {index.m}x{index.n_seg}, "
          f"{index.nbytes() / 2**20:.1f} MiB, "
          f"{jax.device_count()} device(s)")

    cfg = SearchConfig(k=args.k, mu=args.mu, eta=args.eta)

    if args.devices and jax.device_count() >= 4:
        if args.churn or args.save_dir or args.budget_ms:
            print("[serve] warning: --churn/--save-dir/--budget-ms are "
                  "ignored on the distributed (--devices) path")
        mesh = jax.make_mesh((jax.device_count() // 2, 2),
                             ("data", "model"))
        ispecs = index_shard_specs(index)
        i_shard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), ispecs,
            is_leaf=lambda x: isinstance(x, P))
        index = jax.device_put(index, i_shard)
        print("[serve] sharded over", dict(zip(mesh.axis_names,
                                               mesh.devices.shape)))

        import time
        lat = []
        with mesh:
            for step in range(args.batches):
                q, _ = make_queries(spec, args.batch_size, doc_topic,
                                    seed=step)
                q = jax.device_put(q, jax.tree_util.tree_map(
                    lambda _: NamedSharding(mesh, P("model", None)), q,
                    is_leaf=lambda x: hasattr(x, "shape")))
                t0 = time.perf_counter()
                out = jax.block_until_ready(
                    distributed_retrieve(index, q, cfg, mesh))
                lat.append((time.perf_counter() - t0) * 1e3
                           / args.batch_size)
        print(f"[serve] distributed: mean {np.mean(lat[1:]):.2f} ms/q "
              f"p99 {np.percentile(lat[1:], 99):.2f}")
        return

    writer = None
    if args.churn > 0:
        # synthetic churn docs have no dense representation, so placement
        # is least-loaded; pass centroids + dense_rep for real corpora
        writer = IndexWriter(index, seed=9)
        source = writer.publisher
    else:
        source = index
    ab = (AdaptiveBudget(args.budget_ms, init_cost_ms=0.05)
          if args.budget_ms > 0 else None)
    eng = RetrievalEngine(source, cfg, adaptive=ab)
    warm, _ = make_queries(spec, args.batch_size, doc_topic, seed=997)
    eng.warmup(warm)

    rng = np.random.default_rng(123)
    out = None
    for step in range(args.batches):
        if writer is not None:
            live = writer.mutable.live_ids()
            for d in rng.choice(live, min(args.churn, live.size),
                                replace=False):
                writer.delete(int(d))
            # cap inserts at remaining capacity so a churn rate above the
            # delete rate degrades to steady state instead of overflowing
            free = int(writer.mutable.free_slots.sum())
            for _ in range(min(args.churn, free)):
                nnz = int(rng.integers(4, 24))
                t = rng.choice(spec.vocab, nnz, replace=False)
                w = rng.lognormal(0.0, 0.6, nnz).astype(np.float32)
                writer.insert(t, w)
            snap = writer.commit()
        q, _ = make_queries(spec, args.batch_size, doc_topic, seed=step)
        out = eng.search(q)

    s = eng.stats
    line = (f"[serve] {s.n_queries} queries: mean {s.mean_ms:.2f} ms/q, "
            f"p50 {s.p(50):.2f}, p99 {s.p(99):.2f}")
    if out is not None:
        line += (f"; last batch scored "
                 f"{float(out.n_scored_clusters.mean()):.1f}"
                 f"/{index.m} clusters")
    if writer is not None:
        line += (f"; epoch {eng.last_epoch}, "
                 f"{writer.mutable.n_compactions} compaction(s)")
    if ab is not None:
        line += f"; adaptive budget -> {ab.budget()} clusters"
    print(line)

    if args.save_dir:
        final = eng.index
        epoch = eng.last_epoch or 0
        save_index(args.save_dir, final, epoch=epoch,
                   n_shards=min(4, final.m))
        print(f"[serve] saved epoch {epoch} -> {args.save_dir}")


if __name__ == "__main__":
    main()
