"""Serving launcher: ``python -m repro.launch.serve [...]``.

Builds an ASC cluster-skipping index over a synthetic corpus (or cold
starts from a saved one via --load-dir) and serves query batches through
the RetrievalEngine, printing a registry-backed summary of latency
percentiles and the pruning funnel.

Lifecycle options:
  --churn N       between batches, delete+insert N docs through the
                  IndexWriter and publish a new epoch; the engine serves
                  from the SnapshotPublisher, pinning one epoch per batch.
  --budget-ms T   adaptive latency target: the engine's AdaptiveBudget
                  feedback loop retargets the cluster budget per batch
                  (traced scalar — no recompiles).
  --save-dir D    persist the final index (versioned npz shards).
  --load-dir D    cold-start from a persisted index instead of building.

Durability options (docs/lifecycle.md §durability; --churn only):
  --durable-dir D    crash-safe write plane: WAL + checksummed
                     checkpoints under D. When D already holds a
                     checkpoint the process *recovers* from it (replaying
                     the WAL tail) instead of building an index — so
                     SIGKILL + restart resumes serving where the log
                     ends. Writer faults degrade serving to the
                     last-good epoch while recovery retries with
                     backoff; SIGTERM/Ctrl-C flushes the WAL and writes
                     a final checkpoint before exiting.
  --fsync P          WAL fsync policy: always | interval | off.
  --checkpoint-every N   checkpoint every N commits (0 = only at exit).

Streaming options (docs/serving.md):
  --frontend M       off | closed | open. With closed/open the launcher
                     feeds queries one at a time (optionally paced by
                     --arrival-qps) through the StreamingFrontend's
                     bounded queue with per-request deadlines
                     (--deadline-ms), shedding over-capacity submits
                     (--max-queue). ``closed`` additionally runs the
                     (mu, eta)/budget degradation ladder against
                     --slo-p99-ms; SIGTERM stops intake, drains under
                     --drain-deadline-ms, then checkpoints.

Observability options (docs/observability.md):
  --metrics-port P   serve Prometheus text on http://0.0.0.0:P/metrics
                     (and a JSON snapshot on /metrics.json) while the
                     loop runs.
  --metrics-json F   at exit, write the registry snapshot to F (JSON)
                     and the Prometheus exposition next to it (.prom) —
                     the CI smoke job validates both offline.
  --trace-dir D      write per-request Chrome-trace JSON (Perfetto-
                     loadable) under D; --trace-every N samples every
                     Nth request.
  --profile-first-n N  additionally wrap the first N requests in a
                     jax.profiler device capture under D/jax_profile.
  --split-every N    every Nth request, split planner vs executor wall
                     time into the registry (0 = only traced requests).

With ``--devices N`` the index is sharded over a forced host mesh and
served through the shard_map selective-search path — the same code that
runs on the production (pod, data, model) mesh.
"""

import argparse
import json
import os


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=6000)
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--clusters", type=int, default=64)
    ap.add_argument("--segments", type=int, default=8)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--mu", type=float, default=0.9)
    ap.add_argument("--eta", type=float, default=1.0)
    ap.add_argument("--engine", type=str, default="auto",
                    choices=["auto", "per_query", "batched", "pipelined"],
                    help="search engine; pipelined = device wave "
                         "planning with plan/execute dispatch loop")
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--budget-ms", type=float, default=0.0,
                    help="latency target (0 = unbudgeted)")
    ap.add_argument("--churn", type=int, default=0,
                    help="docs deleted+inserted between batches")
    ap.add_argument("--save-dir", type=str, default="")
    ap.add_argument("--load-dir", type=str, default="")
    ap.add_argument("--durable-dir", type=str, default="",
                    help="crash-safe write plane (WAL + checkpoints) "
                         "under this directory; recovers from it when "
                         "it already holds a checkpoint")
    ap.add_argument("--fsync", type=str, default="interval",
                    choices=("always", "interval", "off"),
                    help="WAL fsync policy (--durable-dir)")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    help="checkpoint every N commits (0 = only at exit)")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="serve /metrics on this port (0 = off)")
    ap.add_argument("--metrics-json", type=str, default="",
                    help="write registry snapshot JSON (+ .prom text) "
                         "here at exit")
    ap.add_argument("--trace-dir", type=str, default="",
                    help="write per-request Chrome-trace JSON here")
    ap.add_argument("--trace-every", type=int, default=1,
                    help="trace every Nth request")
    ap.add_argument("--profile-first-n", type=int, default=0,
                    help="jax.profiler capture for the first N requests")
    ap.add_argument("--split-every", type=int, default=0,
                    help="planner/executor split every Nth request "
                         "(0 = only on traced requests)")
    ap.add_argument("--frontend", type=str, default="off",
                    choices=("off", "closed", "open"),
                    help="streaming front-end mode: off = offline "
                         "batches (default); closed = deadline-aware "
                         "queue with the closed-loop (mu, eta) "
                         "degradation ladder; open = same queue with "
                         "the ladder disabled (baseline)")
    ap.add_argument("--arrival-qps", type=float, default=0.0,
                    help="frontend mode: pace submits at this rate "
                         "(0 = as fast as possible)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="frontend mode: bounded queue depth; beyond "
                         "it submits are shed with a typed Rejected")
    ap.add_argument("--deadline-ms", type=float, default=200.0,
                    help="frontend mode: per-request deadline")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0,
                    help="frontend mode: p99 SLO the degradation "
                         "controller defends")
    ap.add_argument("--drain-deadline-ms", type=float, default=1000.0,
                    help="frontend mode: graceful-shutdown drain "
                         "budget; queued requests past it are shed")
    return ap.parse_args()


def _summary(registry, stats, index_m: int) -> str:
    """The end-of-run report, rendered from the registry snapshot —
    the same numbers /metrics exposes, not a parallel accounting."""
    snap = registry.snapshot()

    def scalar(name, default=0.0):
        v = snap.get(name, default)
        return v if not isinstance(v, dict) else default

    lines = [f"[serve] {stats.n_queries} queries in "
             f"{stats.n_requests} batches: mean {stats.mean_ms:.2f} ms/q, "
             f"batch p50 {stats.p(50):.2f} ms, p99 {stats.p(99):.2f} ms"]
    walked = scalar("funnel_tiles_walked_total")
    if walked:
        lines.append(
            "[serve] funnel: "
            f"{scalar('funnel_clusters_budgeted_total'):.0f} budgeted -> "
            f"{scalar('funnel_clusters_scored_total'):.0f} clusters -> "
            f"{walked:.0f} tiles walked -> "
            f"{scalar('funnel_tiles_scored_total'):.0f} scored -> "
            f"{scalar('funnel_doc_slots_walked_total'):.0f} doc slots -> "
            f"{scalar('funnel_docs_scored_total'):.0f} docs scored "
            f"(tile {scalar('funnel_tile_compaction_ratio'):.2f}, "
            f"doc {scalar('funnel_doc_compaction_ratio'):.2f})")
    if scalar("split_requests_total"):
        lines.append(
            f"[serve] planner share {scalar('planner_share'):.2f} "
            f"over {scalar('split_requests_total'):.0f} sampled "
            f"split(s)")
    if scalar("lifecycle_epoch_swaps_total"):
        lines.append(
            f"[serve] lifecycle: epoch {scalar('lifecycle_epoch'):.0f}, "
            f"{scalar('lifecycle_epoch_swaps_total'):.0f} swap(s), "
            f"{scalar('index_compactions_total'):.0f} compaction(s), "
            f"slack {scalar('index_slack'):.3f}, unsorted tail "
            f"{scalar('index_unsorted_tail_fraction'):.3f}")
    if scalar("adaptive_budget_clusters"):
        lines.append(
            f"[serve] adaptive budget -> "
            f"{min(scalar('adaptive_budget_clusters'), index_m):.0f}"
            f"/{index_m} clusters "
            f"(cost {scalar('adaptive_cost_ms'):.4f} ms/cluster)")
    return "\n".join(lines)


def _dump_metrics(registry, path: str) -> None:
    """Snapshot JSON at ``path`` + Prometheus text next to it, so CI
    can validate both expositions without racing an HTTP server."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(registry.snapshot(), f, indent=1)
    prom = os.path.splitext(path)[0] + ".prom"
    with open(prom, "w") as f:
        f.write(registry.render_prometheus())
    print(f"[serve] metrics -> {path} + {prom}")


def _apply_churn(writer, rng, spec, n: int, registry) -> None:
    """One churn round: N deletes + up-to-N inserts + commit. An
    ``IndexFullError`` does not kill the round (or the process): force a
    compaction, back off, retry the insert; persistently-full indexes
    skip the rest of the round's inserts instead of failing serving."""
    import time as _time

    import numpy as np

    from repro.lifecycle import IndexFullError

    live = writer.mutable.live_ids()
    for d in rng.choice(live, min(n, live.size), replace=False):
        writer.delete(int(d))
    # cap inserts at remaining capacity so a churn rate above the
    # delete rate degrades to steady state instead of overflowing
    free = int(writer.mutable.free_slots.sum())
    for _ in range(min(n, free)):
        nnz = int(rng.integers(4, 24))
        t = rng.choice(spec.vocab, nnz, replace=False)
        w = rng.lognormal(0.0, 0.6, nnz).astype(np.float32)
        backoff = 0.02
        for attempt in range(3):
            try:
                writer.insert(t, w)
                break
            except IndexFullError:
                registry.counter(
                    "serve_index_full_total",
                    "inserts rejected by a full index (forced "
                    "compaction + backoff + retry)").inc()
                writer.mutable.compact()
                _time.sleep(backoff)
                backoff *= 2
        else:
            print("[serve] index full even after compaction; "
                  "skipping remaining inserts this round")
            break
    writer.commit()


def _recover_writer(eng, args, registry, backoff_cap_s: float = 2.0):
    """Bounded-retry recovery of the durable write plane. Readers keep
    serving the engine's last-good pinned epoch the whole time; the
    publisher only swaps forward when recovery republishes. Retries
    back off exponentially to ``backoff_cap_s`` with up to 25% jitter
    (a fleet restarting against one shared volume must not retry in
    lockstep); every attempt increments
    ``writer_recovery_attempts_total``."""
    import time as _time

    import numpy as np

    from repro.lifecycle import DurableIndexWriter

    attempts = registry.counter(
        "writer_recovery_attempts_total",
        "write-plane recovery attempts (success and failure)")
    rng = np.random.default_rng(17)
    backoff = 0.1
    last: Exception | None = None
    for attempt in range(5):
        attempts.inc()
        try:
            eng.health.to("recovering", f"recovery attempt {attempt + 1}")
            writer = DurableIndexWriter.recover(
                args.durable_dir, fsync=args.fsync,
                checkpoint_every=args.checkpoint_every,
                publisher=eng._source, registry=registry)
            eng.health.to("healthy", "recovered")
            print(f"[serve] write plane recovered: {writer.recovery_stats}")
            return writer
        except Exception as e:          # noqa: BLE001 — retry any failure
            last = e
            eng.health.to("degraded", f"recovery failed: {e!r}")
            sleep_s = min(backoff, backoff_cap_s) * (
                1.0 + 0.25 * float(rng.random()))
            print(f"[serve] recovery attempt {attempt + 1} failed: {e!r}; "
                  f"retrying in {sleep_s:.2f}s")
            _time.sleep(sleep_s)
            backoff = min(backoff * 2, backoff_cap_s)
    raise RuntimeError(
        f"write-plane recovery failed after retries: {last!r}")


def main() -> None:
    args = _parse()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.clustering import (balanced_assign,
                                       dense_rep_projection, lloyd_kmeans)
    from repro.core.index import build_index
    from repro.core.search import SearchConfig, retrieve
    from repro.data.synthetic import CorpusSpec, make_corpus, make_queries
    from repro.lifecycle import IndexWriter, load_index, save_index
    from repro.obs import MetricsRegistry, Observability
    from repro.serving.engine import (AdaptiveBudget, RetrievalEngine,
                                      ServeStats, distributed_retrieve,
                                      index_shard_specs)

    want_obs = bool(args.metrics_port or args.metrics_json
                    or args.trace_dir or args.profile_first_n
                    or args.split_every)
    obs = Observability(
        trace_dir=args.trace_dir or None,
        trace_sample_every=max(args.trace_every, 1),
        profile_first_n=args.profile_first_n,
        split_every=args.split_every) if want_obs else None
    registry = obs.registry if obs is not None else MetricsRegistry()

    server = None
    if args.metrics_port:
        from repro.obs.exposition import MetricsServer
        server = MetricsServer(registry, port=args.metrics_port)
        print(f"[serve] /metrics on port {server.port}")

    spec = CorpusSpec(n_docs=args.n_docs, vocab=args.vocab,
                      n_topics=max(8, args.clusters // 2))
    docs, doc_topic = make_corpus(spec)
    if args.load_dir:
        index, manifest = load_index(args.load_dir)
        print(f"[serve] cold start from {args.load_dir} "
              f"(epoch {manifest['epoch']}, v{manifest['format_version']})")
        if index.vocab != spec.vocab:
            raise SystemExit(
                f"[serve] queries are generated over --vocab {spec.vocab} "
                f"but the loaded index covers vocab {index.vocab}; pass a "
                f"matching --vocab with --load-dir")
    else:
        rep = dense_rep_projection(docs, dim=96)
        centers, _ = lloyd_kmeans(jax.random.PRNGKey(0), rep,
                                  k=args.clusters, iters=8)
        d_pad = int(2.0 * args.n_docs / args.clusters)
        assign = balanced_assign(rep, centers, capacity=d_pad)
        index = build_index(docs, np.asarray(assign), m=args.clusters,
                            n_seg=args.segments, d_pad=d_pad)
    print(f"[serve] index: {index.m}x{index.n_seg}, "
          f"{index.nbytes() / 2**20:.1f} MiB, "
          f"{jax.device_count()} device(s)")

    cfg = SearchConfig(k=args.k, mu=args.mu, eta=args.eta,
                       engine=args.engine)

    if args.devices and jax.device_count() >= 4:
        if args.churn or args.save_dir or args.budget_ms:
            print("[serve] warning: --churn/--save-dir/--budget-ms are "
                  "ignored on the distributed (--devices) path")
        mesh = jax.make_mesh((jax.device_count() // 2, 2),
                             ("data", "model"))
        ispecs = index_shard_specs(index)
        i_shard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), ispecs,
            is_leaf=lambda x: isinstance(x, P))
        index = jax.device_put(index, i_shard)
        print("[serve] sharded over", dict(zip(mesh.axis_names,
                                               mesh.devices.shape)))

        import time
        # record through the same registry-backed accounting as the
        # single-host engine, so the summary and exposition match
        dstats = ServeStats(registry=registry)

        def shard_queries(q):
            return jax.device_put(q, jax.tree_util.tree_map(
                lambda _: NamedSharding(mesh, P("model", None)), q,
                is_leaf=lambda x: hasattr(x, "shape")))

        with mesh:
            # untimed warmup batch: pay jit compilation outside the
            # recorded loop (no registry — warmup is not traffic), so
            # dstats never folds compile time into the latency stats
            warm, _ = make_queries(spec, args.batch_size, doc_topic,
                                   seed=997)
            jax.block_until_ready(distributed_retrieve(
                index, shard_queries(warm), cfg, mesh))
            for step in range(args.batches):
                q, _ = make_queries(spec, args.batch_size, doc_topic,
                                    seed=step)
                q = shard_queries(q)
                t0 = time.perf_counter()
                out = jax.block_until_ready(
                    distributed_retrieve(
                        index, q, cfg, mesh,
                        registry=registry if obs is not None else None))
                dstats.record(args.batch_size,
                              time.perf_counter() - t0)
        print(_summary(registry, dstats, index.m))
        if args.metrics_json:
            _dump_metrics(registry, args.metrics_json)
        if server is not None:
            server.close()
        return

    writer = None
    if args.churn > 0:
        # synthetic churn docs have no dense representation, so placement
        # is least-loaded; pass centroids + dense_rep for real corpora
        if args.durable_dir:
            from repro.lifecycle import DurableIndexWriter
            from repro.lifecycle.wal import SNAPSHOT_SUBDIR
            if os.path.exists(os.path.join(args.durable_dir,
                                           SNAPSHOT_SUBDIR)):
                writer = DurableIndexWriter.recover(
                    args.durable_dir, fsync=args.fsync,
                    checkpoint_every=args.checkpoint_every,
                    registry=registry)
                print(f"[serve] recovered write plane from "
                      f"{args.durable_dir}: {writer.recovery_stats}")
            else:
                writer = DurableIndexWriter(
                    index, args.durable_dir, fsync=args.fsync,
                    checkpoint_every=args.checkpoint_every, seed=9,
                    registry=registry)
                print(f"[serve] durable write plane -> {args.durable_dir} "
                      f"(fsync={args.fsync})")
        else:
            writer = IndexWriter(index, seed=9, registry=registry)
        source = writer.publisher
    else:
        source = index
    ab = (AdaptiveBudget(args.budget_ms, init_cost_ms=0.05)
          if args.budget_ms > 0 else None)
    eng = RetrievalEngine(source, cfg, adaptive=ab, obs=obs)
    if obs is None:
        # no obs flags: the engine still accounts into `registry` so the
        # final summary renders from one source of truth
        eng.stats = ServeStats(registry=registry)
    warm, _ = make_queries(spec, args.batch_size, doc_topic, seed=997)
    eng.warmup(warm)

    # SIGTERM gets the same graceful path as Ctrl-C: flush the WAL,
    # final checkpoint, metrics dump — a signal is not a crash
    import signal

    def _on_sigterm(signum, frame):
        raise KeyboardInterrupt

    try:
        signal.signal(signal.SIGTERM, _on_sigterm)
    except ValueError:
        pass                             # not the main thread (tests)

    frontend = None
    if args.frontend != "off":
        from repro.serving.frontend import FrontendConfig, StreamingFrontend
        frontend = StreamingFrontend(eng, FrontendConfig(
            max_batch=args.batch_size, max_queue=args.max_queue,
            default_deadline_ms=args.deadline_ms,
            slo_p99_ms=args.slo_p99_ms,
            drain_deadline_ms=args.drain_deadline_ms,
            closed_loop=(args.frontend == "closed")))
        from repro.serving.frontend import query_rows as _rows
        frontend.warmup(next(_rows(warm)))
        frontend.start()
        print(f"[serve] streaming frontend ({args.frontend} loop): "
              f"queue<={args.max_queue}, deadline {args.deadline_ms:.0f} "
              f"ms, SLO p99 {args.slo_p99_ms:.0f} ms")

    rng = np.random.default_rng(123)
    out = None
    try:
        import time as _time

        from repro.serving.frontend import query_rows
        interval_s = (1.0 / args.arrival_qps
                      if args.arrival_qps > 0 else 0.0)
        futures = []
        for step in range(args.batches):
            if writer is not None:
                try:
                    _apply_churn(writer, rng, spec, args.churn, registry)
                except KeyboardInterrupt:
                    raise
                except Exception as e:   # noqa: BLE001
                    # a mid-mutation writer fault leaves the in-memory
                    # index untrustworthy; readers stay on the last-good
                    # epoch while the durable state is recovered
                    if not args.durable_dir:
                        raise
                    print(f"[serve] write plane fault: {e!r} — serving "
                          f"degraded from last-good epoch")
                    if eng.health.healthy:
                        eng.health.to("degraded", repr(e))
                    writer = _recover_writer(eng, args, registry)
            q, _ = make_queries(spec, args.batch_size, doc_topic,
                                seed=step)
            if frontend is None:
                out = eng.search(q)
            else:
                for row in query_rows(q):
                    futures.append(frontend.submit(row))
                    if interval_s:
                        _time.sleep(interval_s)
        for f in futures:
            f.result()                   # typed outcome, never hangs
    except KeyboardInterrupt:
        print("[serve] interrupted — shutting down gracefully")
    finally:
        # graceful-drain ordering: stop intake and drain the queue
        # under its bounded deadline FIRST, so in-flight requests see a
        # consistent epoch; only then flush the WAL + final checkpoint
        if frontend is not None:
            drained = frontend.shutdown()
            cons = frontend.conservation()
            print(f"[serve] frontend drained: {drained['drained']} "
                  f"served, {drained['shed']} shed at deadline; "
                  f"totals {cons} (ladder max level "
                  f"{frontend.controller.level_max})")
        if writer is not None and hasattr(writer, "close"):
            writer.close()               # WAL flush + final checkpoint
            print(f"[serve] final checkpoint -> {args.durable_dir}")

        print(_summary(registry, eng.stats, index.m))
        if out is not None and obs is None:
            # without obs the funnel counters are empty; keep the quick
            # work-counter readout from the last batch
            print(f"[serve] last batch scored "
                  f"{float(out.n_scored_clusters.mean()):.1f}"
                  f"/{index.m} clusters")

        if args.metrics_json:
            _dump_metrics(registry, args.metrics_json)
        if server is not None:
            server.close()

        if args.save_dir:
            final = eng.index
            epoch = eng.last_epoch or 0
            save_index(args.save_dir, final, epoch=epoch,
                       n_shards=min(4, final.m))
            print(f"[serve] saved epoch {epoch} -> {args.save_dir}")


if __name__ == "__main__":
    main()
