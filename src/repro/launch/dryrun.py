import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks the device count on first
# init). 512 placeholder host devices back the production meshes; nothing
# here allocates real buffers — cells are lowered from ShapeDtypeStructs.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: ``jax.jit(step, in_shardings, out_shardings).lower(*abstract
args).compile()`` on the 16x16 single-pod mesh and the 2x16x16 multi-pod
mesh, then record
  * memory_analysis (bytes per device — proves it fits),
  * cost_analysis (HLO FLOPs / bytes accessed),
  * per-collective byte totals parsed from the compiled HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute),
into ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` — the roofline
inputs (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--force]
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro.distributed.sharding import use_rules
from repro.launch.cells import all_cells, build_cell, layer_count
from repro.launch.mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "s16": 2, "s32": 4, "s64": 8,
    "u4": 1, "u8": 1, "u16": 2, "u32": 4, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one HLO shape like 'bf16[8,128,2048]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_RE = re.compile(
    r"=\s*(\(?[^=()]*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def parse_collectives(hlo_text: str) -> dict[str, dict]:
    """Sum result-shape bytes per collective op kind from HLO text.

    Async pairs: the payload is attributed to the ``-start`` op; ``-done``
    ops are skipped (their result aliases the started buffer)."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(shape_str)
    return out


def _add_layer_extrapolation(rec: dict, arch: str, shape: str, mesh,
                             multi_pod: bool) -> None:
    """Honest per-layer costs for scan-over-layers programs.

    XLA cost analysis counts a while-loop body ONCE, so the u=1 production
    compile under-counts an L-layer model by ~L. A second *counting*
    compile at unroll=u (u | L) gives per-layer = (f_u - f_1)/(u - 1)
    (verified exactly linear), and total = f_1 + (L-1) * per-layer. The
    same extrapolation applies to bytes and per-collective payloads.
    """
    L = layer_count(arch)
    if L <= 1:
        rec["flops_total"] = rec["flops"]
        rec["bytes_total"] = rec["bytes_accessed"]
        rec["collectives_total"] = rec["collectives"]
        return
    u = 2 if L % 2 == 0 else (3 if L % 3 == 0 else L)
    plan = build_cell(arch, shape, mesh, multi_pod, unroll=u)
    with mesh, use_rules(plan.rules):
        compiled = jax.jit(
            plan.fn, in_shardings=plan.in_shardings,
            out_shardings=plan.out_shardings,
            donate_argnums=plan.donate_argnums).lower(*plan.args).compile()
        cost = compiled.cost_analysis()
        coll_u = parse_collectives(compiled.as_text())

    def extrap(f1, fu):
        per_layer = max(0.0, (fu - f1) / (u - 1))
        return f1 + (L - 1) * per_layer

    rec["counting_unroll"] = u
    rec["flops_total"] = extrap(rec["flops"],
                                float(cost.get("flops", 0.0)))
    rec["bytes_total"] = extrap(rec["bytes_accessed"],
                                float(cost.get("bytes accessed", 0.0)))
    rec["collectives_total"] = {
        k: {"count": int(extrap(rec["collectives"][k]["count"],
                                coll_u[k]["count"])),
            "bytes": extrap(rec["collectives"][k]["bytes"],
                            coll_u[k]["bytes"])}
        for k in rec["collectives"]}


def run_cell(arch: str, shape: str, mesh_kind: str,
             save: bool = True) -> dict:
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rec: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "n_devices": n_dev, "status": "error",
    }
    t0 = time.perf_counter()
    try:
        plan = build_cell(arch, shape, mesh, multi_pod)
        rec.update({"mode": plan.mode, "model_flops": plan.model_flops,
                    "notes": plan.notes})
        with mesh, use_rules(plan.rules):
            jitted = jax.jit(
                plan.fn, in_shardings=plan.in_shardings,
                out_shardings=plan.out_shardings,
                donate_argnums=plan.donate_argnums)
            lowered = jitted.lower(*plan.args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                k: int(getattr(mem, k, 0) or 0)
                for k in ("argument_size_in_bytes",
                          "output_size_in_bytes",
                          "temp_size_in_bytes",
                          "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
            },
            "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
            "bytes_accessed": float(cost.get("bytes accessed", 0.0))
            if cost else 0.0,
            "collectives": parse_collectives(hlo),
            "hlo_lines": hlo.count("\n"),
        })
        _add_layer_extrapolation(rec, arch, shape, mesh, multi_pod)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.perf_counter() - t0, 2)
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        path = os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_kind}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_fail = n_skip = 0
    for arch, shape in cells:
        for mk in meshes:
            path = os.path.join(OUT_DIR, f"{arch}__{shape}__{mk}.json")
            if not args.force and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") == "ok":
                        n_skip += 1
                        continue
            rec = run_cell(arch, shape, mk)
            tag = "OK " if rec["status"] == "ok" else "FAIL"
            if rec["status"] == "ok":
                n_ok += 1
                per_dev = (rec["memory"]["argument_size_in_bytes"]
                           + rec["memory"]["temp_size_in_bytes"]) / 2**30
                print(f"[{tag}] {arch:22s} {shape:14s} {mk:6s} "
                      f"compile={rec['compile_s']:.1f}s "
                      f"mem/dev={per_dev:.2f}GiB "
                      f"flops={rec['flops']:.3g}", flush=True)
            else:
                n_fail += 1
                print(f"[{tag}] {arch:22s} {shape:14s} {mk:6s} "
                      f"{rec['error']}", flush=True)
    print(f"done: ok={n_ok} fail={n_fail} skipped={n_skip}", flush=True)


if __name__ == "__main__":
    main()
