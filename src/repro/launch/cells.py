"""Cell definitions: (architecture x input shape) -> lowerable step.

``build_cell(arch, shape, mesh, multi_pod)`` returns a :class:`CellPlan`
with the function to lower, abstract arg shapes (ShapeDtypeStructs — no
allocation), in/out shardings, donation, the ambient sharding rules, and
MODEL_FLOPS (the hand-counted useful FLOPs for §Roofline's
MODEL/HLO-FLOPs ratio).

Shape sets follow the assignment table verbatim; ``molecule`` is flattened
to one disjoint-union graph, ``minibatch_lg`` uses the neighbour-sampler
output geometry (seeds + fanout 15-10), encoder-only/recsys archs have no
decode cells by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import arch_kind, get_arch
from repro.distributed import sharding as sh
from repro.training import optimizer as opt_lib

I32 = jnp.int32
F32 = jnp.float32
BOOL = jnp.bool_


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: str
    mode: str
    fn: Callable
    args: tuple                      # ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any               # or None (let XLA choose)
    donate_argnums: tuple
    rules: sh.ShardingRules
    model_flops: float
    notes: str = ""


LM_SHAPES = {
    "train_4k": {"mode": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"mode": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"mode": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"mode": "decode", "seq": 524288, "batch": 1},
}

GNN_SHAPES = {
    # Cora-geometry full batch
    "full_graph_sm": {"mode": "train", "n_nodes": 2708, "n_edges": 10556,
                      "d_feat": 1433, "d_edge": 16, "node_out": 7},
    # Reddit-geometry sampled training: seeds + fanout (15, 10)
    "minibatch_lg": {"mode": "train", "batch_nodes": 1024,
                     "fanout": (15, 10), "d_feat": 602, "d_edge": 16,
                     "node_out": 41},
    # ogbn-products full batch
    "ogb_products": {"mode": "train", "n_nodes": 2_449_029,
                     "n_edges": 61_859_140, "d_feat": 100, "d_edge": 8,
                     "node_out": 47},
    # 128 molecules of 30 nodes / 64 edges, disjoint union
    "molecule": {"mode": "train", "n_graphs": 128, "nodes_per": 30,
                 "edges_per": 64, "d_feat": 16, "d_edge": 8, "node_out": 3},
}

RECSYS_SHAPES = {
    "train_batch": {"mode": "train", "batch": 65536},
    "serve_p99": {"mode": "serve", "batch": 512},
    "serve_bulk": {"mode": "serve", "batch": 262144},
    "retrieval_cand": {"mode": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}

RETRIEVAL_SHAPES = {
    "serve_k10": {"mode": "retrieve", "batch": 256, "k": 10},
    "serve_k1000": {"mode": "retrieve", "batch": 64, "k": 1000},
}

SHAPES_BY_KIND = {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
                  "recsys": RECSYS_SHAPES, "retrieval": RETRIEVAL_SHAPES}


def shapes_for(arch: str) -> list[str]:
    return list(SHAPES_BY_KIND[arch_kind(arch)])


def all_cells() -> list[tuple[str, str]]:
    from repro.configs import list_archs
    out = []
    for arch in list_archs():
        for shape in shapes_for(arch):
            out.append((arch, shape))
    return out


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _shardings(rules: sh.ShardingRules, axes_tree, shapes_tree=None):
    """Logical axes -> NamedShardings; with ``shapes_tree`` given, mesh
    axes that do not divide a dimension are dropped (partial sharding)
    instead of failing compilation — see sharding.divisible_spec."""
    if shapes_tree is not None:
        return sh.shard_with_shapes(rules, axes_tree, shapes_tree)
    def is_axes(x):
        return isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x)
    return jax.tree_util.tree_map(
        lambda axes: NamedSharding(rules.mesh, rules.spec(*axes)),
        axes_tree, is_leaf=is_axes)


def _mlp_flops(dims) -> float:
    return 2.0 * sum(float(dims[i]) * dims[i + 1]
                     for i in range(len(dims) - 1))


# ===========================================================================
# LM cells
# ===========================================================================

def _build_lm(arch: str, shape: str, mesh, multi_pod: bool,
              unroll: int = 1) -> CellPlan:
    from repro.models import transformer as tf
    spec = LM_SHAPES[shape]
    cfg = get_arch(arch).config()
    cfg = dataclasses.replace(cfg, unroll=min(unroll, cfg.n_layers))
    mode = spec["mode"]
    B, S = spec["batch"], spec["seq"]
    rules = sh.lm_rules(mesh, training=(mode == "train"),
                        long_context=(shape == "long_500k"),
                        decode=(mode == "decode"))

    # training holds f32 master weights; serving artifacts are bf16
    # checkpoints (halves the weight-read bytes of every decode step)
    param_dtype = jnp.float32 if mode == "train" else jnp.bfloat16
    params_shapes = jax.eval_shape(
        lambda: tf.init_params(jax.random.PRNGKey(0), cfg, param_dtype))
    p_axes = tf.param_axes(cfg)
    p_shard = _shardings(rules, p_axes, params_shapes)

    n_act = cfg.active_param_count()
    L, h, d = cfg.n_layers, cfg.n_heads, cfg.head_dim

    if mode == "train":
        from repro.training.train_loop import TrainConfig, make_train_step
        optimizer = opt_lib.adamw(opt_lib.cosine_schedule(3e-4, 100, 1000))
        # NOTE(perf, llama4 iter 6 — refuted): microbatches=4 shrinks the
        # logits/CE footprint but re-gathers FSDP weights per microbatch
        # (4x weight traffic) and peak memory barely moves because remat
        # already bounds activations. Kept at 1; the fit-on-v5e answer for
        # llama4-scout is the multi-pod mesh (see EXPERIMENTS.md §Perf).
        step = make_train_step(lambda p, b: tf.loss_fn(p, b, cfg),
                               optimizer, TrainConfig(),
                               grad_shardings=p_shard)
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        opt_shard = {"mu": p_shard, "nu": p_shard}
        batch_shapes = {
            "tokens": _sds((B, S), I32), "labels": _sds((B, S), I32),
            "mask": _sds((B, S), F32)}
        b_shard = {
            "tokens": rules.sharding("batch", "seq"),
            "labels": rules.sharding("batch", "seq"),
            "mask": rules.sharding("batch", "seq")}
        args = (params_shapes, opt_shapes, batch_shapes, _sds((), I32))
        in_sh = (p_shard, opt_shard, b_shard, NamedSharding(mesh, P()))
        out_sh = (p_shard, opt_shard, None)
        flops = 6.0 * n_act * B * S + 6.0 * B * S * S * h * d * L
        return CellPlan(arch, shape, mode, step, args, in_sh, out_sh,
                        (0, 1), rules, flops)

    if mode == "prefill":
        fn = lambda p, t: tf.prefill(p, t, cfg)
        args = (params_shapes, _sds((B, S), I32))
        in_sh = (p_shard, rules.sharding("batch", "seq"))
        cache_shapes = jax.eval_shape(
            lambda p, t: tf.prefill(p, t, cfg),
            params_shapes, _sds((B, S), I32))[1]
        c_shard = _shardings(rules, tf.cache_axes(), cache_shapes)
        # prefill emits last-token logits (B, 1, V): seq dim is 1 — only
        # batch and vocab shard.
        out_sh = (rules.sharding("batch", None, "vocab"), c_shard)
        flops = 2.0 * n_act * B * S + 2.0 * B * S * S * h * d * L
        return CellPlan(arch, shape, mode, fn, args, in_sh, out_sh, (),
                        rules, flops)

    # decode
    fn = lambda p, c, t: tf.decode_step(p, c, t, cfg)
    cache_shapes = jax.eval_shape(
        lambda: tf.init_cache(cfg, B, S, jnp.bfloat16))
    c_shard = _shardings(rules, tf.cache_axes(), cache_shapes)
    args = (params_shapes, cache_shapes, _sds((B, 1), I32))
    # decode tokens/logits have seq dim 1 — never shard it.
    in_sh = (p_shard, c_shard, rules.sharding("batch", None))
    out_sh = (rules.sharding("batch", None, "vocab"), c_shard)
    flops = 2.0 * n_act * B + 4.0 * B * S * cfg.n_kv_heads * d * (
        cfg.n_heads // cfg.n_kv_heads) * L
    return CellPlan(arch, shape, mode, fn, args, in_sh, out_sh, (1,),
                    rules, flops)


# ===========================================================================
# GNN cells
# ===========================================================================

def _gnn_geometry(spec: dict) -> tuple[int, int]:
    if "n_nodes" in spec:
        return spec["n_nodes"], spec["n_edges"]
    if "batch_nodes" in spec:                      # sampled minibatch
        n, e = spec["batch_nodes"], 0
        frontier = spec["batch_nodes"]
        for f in spec["fanout"]:
            e += frontier * f
            frontier *= f
            n += frontier
        return n, e
    n = spec["n_graphs"] * spec["nodes_per"]       # molecule union
    e = spec["n_graphs"] * spec["edges_per"]
    return n, e


def _build_gnn(arch: str, shape: str, mesh, multi_pod: bool,
               unroll: int = 1) -> CellPlan:
    from repro.models import gnn
    from repro.training.train_loop import TrainConfig, make_train_step
    spec = GNN_SHAPES[shape]
    N, E = _gnn_geometry(spec)
    # pad node/edge counts to the shard grid (the data pipeline emits
    # masked padding nodes/edges — node_mask/edge_mask already exist);
    # 512 = lcm of both production meshes' combined data axes.
    N, E = -(-N // 512) * 512, -(-E // 512) * 512
    cfg = get_arch(arch).config(node_in=spec["d_feat"],
                                edge_in=spec["d_edge"],
                                node_out=spec["node_out"])
    cfg = dataclasses.replace(cfg, unroll=min(unroll, cfg.n_layers))
    rules = sh.gnn_rules(mesh)

    params_shapes = jax.eval_shape(
        lambda: gnn.init_params(jax.random.PRNGKey(0), cfg))
    p_shard = _shardings(rules, gnn.param_axes(cfg), params_shapes)

    optimizer = opt_lib.adamw(opt_lib.cosine_schedule(1e-4, 100, 1000))
    step = make_train_step(lambda p, b: gnn.loss_fn(p, b, cfg), optimizer,
                           TrainConfig(), grad_shardings=p_shard)
    opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
    opt_shard = {"mu": p_shard, "nu": p_shard}

    graph_shapes = {
        "node_feat": _sds((N, spec["d_feat"]), F32),
        "edge_feat": _sds((E, spec["d_edge"]), F32),
        "senders": _sds((E,), I32), "receivers": _sds((E,), I32),
        "node_mask": _sds((N,), BOOL), "edge_mask": _sds((E,), BOOL),
        "target": _sds((N, spec["node_out"]), F32),
    }
    g_shard = {
        "node_feat": rules.sharding("nodes", "feat"),
        "edge_feat": rules.sharding("edges", "feat"),
        "senders": rules.sharding("edges"),
        "receivers": rules.sharding("edges"),
        "node_mask": rules.sharding("nodes"),
        "edge_mask": rules.sharding("edges"),
        "target": rules.sharding("nodes", "feat"),
    }
    args = (params_shapes, opt_shapes, graph_shapes, _sds((), I32))
    in_sh = (p_shard, opt_shard, g_shard, NamedSharding(mesh, P()))
    out_sh = (p_shard, opt_shard, None)

    d = cfg.d_hidden
    hid = [d] * cfg.mlp_layers
    fwd = (N * _mlp_flops([cfg.node_in] + hid + [d])
           + E * _mlp_flops([cfg.edge_in] + hid + [d])
           + cfg.n_layers * (E * _mlp_flops([3 * d] + hid + [d])
                             + N * _mlp_flops([2 * d] + hid + [d]))
           + N * _mlp_flops([d] + hid + [cfg.node_out]))
    return CellPlan(arch, shape, "train", step, args, in_sh, out_sh,
                    (0, 1), rules, 3.0 * fwd)


# ===========================================================================
# RecSys cells
# ===========================================================================

def _recsys_batch_shapes(arch: str, cfg, B: int, spec: dict,
                         rules) -> tuple[dict, dict, float]:
    """(shapes, shardings, fwd_flops_per_sample) for a training/serving
    batch of the given arch."""
    if arch == "dlrm-mlperf":
        shapes = {"dense": _sds((B, cfg.n_dense), F32),
                  "sparse": _sds((B, cfg.n_sparse), I32),
                  "labels": _sds((B,), F32)}
        shard = {"dense": rules.sharding("batch", "feat"),
                 "sparse": rules.sharding("batch", "fields"),
                 "labels": rules.sharding("batch")}
        f = cfg.n_sparse + 1
        fwd = (_mlp_flops([cfg.n_dense, *cfg.bot_mlp])
               + _mlp_flops([cfg.top_in, *cfg.top_mlp])
               + 2.0 * f * f * cfg.embed_dim)
    elif arch == "din":
        L = cfg.seq_len
        shapes = {"hist_items": _sds((B, L), I32),
                  "hist_cates": _sds((B, L), I32),
                  "hist_mask": _sds((B, L), BOOL),
                  "target_item": _sds((B,), I32),
                  "target_cate": _sds((B,), I32),
                  "labels": _sds((B,), F32)}
        shard = {k: rules.sharding("batch", "seq") if v.ndim == 2
                 else rules.sharding("batch")
                 for k, v in shapes.items()}
        fdim = cfg.feat_dim
        fwd = (L * _mlp_flops([4 * fdim, *cfg.attn_mlp, 1])
               + _mlp_flops([3 * fdim, *cfg.mlp, 1]) + 2.0 * L * fdim)
    elif arch == "deepfm":
        shapes = {"fields": _sds((B, cfg.n_fields), I32),
                  "labels": _sds((B,), F32)}
        shard = {"fields": rules.sharding("batch", "fields"),
                 "labels": rules.sharding("batch")}
        fwd = (_mlp_flops([cfg.n_fields * cfg.embed_dim, *cfg.mlp, 1])
               + 4.0 * cfg.n_fields * cfg.embed_dim)
    elif arch == "bert4rec":
        L, D = cfg.seq_len, cfg.embed_dim
        shapes = {"items": _sds((B, L), I32), "mask": _sds((B, L), BOOL),
                  "labels": _sds((B, L), I32),
                  "label_mask": _sds((B, L), BOOL),
                  "negatives": _sds((cfg.n_negatives,), I32)}
        shard = {k: rules.sharding("batch", "seq")
                 for k in ("items", "mask", "labels", "label_mask")}
        shard["negatives"] = NamedSharding(rules.mesh, P())
        per_tok = 8.0 * D * D + 4.0 * D * L + 2.0 * 8 * D * D
        fwd = cfg.n_blocks * L * per_tok \
            + L * 2.0 * D * (1 + cfg.n_negatives)
    else:
        raise KeyError(arch)
    return shapes, shard, fwd


def _build_recsys(arch: str, shape: str, mesh, multi_pod: bool,
                  unroll: int = 1) -> CellPlan:
    from repro.models import recsys as rs
    from repro.training.train_loop import TrainConfig, make_train_step
    spec = RECSYS_SHAPES[shape]
    cfg = get_arch(arch).config()
    mode = spec["mode"]
    rules = sh.recsys_rules(mesh)
    B = spec["batch"]

    fns = {
        "dlrm-mlperf": (rs.dlrm_init, rs.dlrm_axes, rs.dlrm_forward,
                        rs.dlrm_loss, rs.dlrm_retrieval),
        "din": (rs.din_init, rs.din_axes, rs.din_forward, rs.din_loss,
                rs.din_retrieval),
        "deepfm": (rs.deepfm_init, rs.deepfm_axes, rs.deepfm_forward,
                   rs.deepfm_loss, rs.deepfm_retrieval),
        "bert4rec": (rs.bert4rec_init, rs.bert4rec_axes, rs.bert4rec_encode,
                     rs.bert4rec_loss, rs.bert4rec_retrieval),
    }
    init_fn, axes_fn, fwd_fn, loss_fn, retr_fn = fns[arch]
    params_shapes = jax.eval_shape(
        lambda: init_fn(jax.random.PRNGKey(0), cfg))
    p_shard = _shardings(rules, axes_fn(cfg), params_shapes)

    if mode == "train":
        shapes, b_shard, fwd = _recsys_batch_shapes(arch, cfg, B, spec,
                                                    rules)
        # row-wise adagrad on the big tables (MLPerf recipe) for DLRM and
        # DeepFM; AdamW elsewhere (tables are small).
        if arch in ("dlrm-mlperf", "deepfm"):
            optimizer = opt_lib.rowwise_adagrad(
                opt_lib.constant_schedule(0.01))
            opt_shard = {"acc": jax.tree_util.tree_map(
                lambda s: NamedSharding(rules.mesh, P(s.spec[0])
                                        if len(s.spec) else P()), p_shard)}
        else:
            optimizer = opt_lib.adamw(opt_lib.constant_schedule(1e-3))
            opt_shard = {"mu": p_shard, "nu": p_shard}
        step = make_train_step(lambda p, b: loss_fn(p, b, cfg), optimizer,
                               TrainConfig())
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        args = (params_shapes, opt_shapes, shapes, _sds((), I32))
        in_sh = (p_shard, opt_shard, b_shard, NamedSharding(mesh, P()))
        out_sh = (p_shard, opt_shard, None)
        return CellPlan(arch, shape, mode, step, args, in_sh, out_sh,
                        (0, 1), rules, 3.0 * fwd * B)

    if mode == "serve":
        shapes, b_shard, fwd = _recsys_batch_shapes(arch, cfg, B, spec,
                                                    rules)
        shapes.pop("labels", None)
        b_shard.pop("labels", None)
        if arch == "bert4rec":
            shapes.pop("label_mask"), shapes.pop("negatives")
            shapes.pop("labels", None)
            b_shard = {k: b_shard[k] for k in shapes}
        fn = lambda p, b: fwd_fn(p, b, cfg)
        args = (params_shapes, shapes)
        return CellPlan(arch, shape, mode, fn, args, (p_shard, b_shard),
                        None, (), rules, fwd * B)

    # retrieval_cand
    C = spec["n_candidates"]
    if arch == "dlrm-mlperf":
        shapes = {"dense": _sds((1, cfg.n_dense), F32),
                  "sparse": _sds((1, cfg.n_sparse), I32),
                  "cand_ids": _sds((C,), I32)}
        _, _, fwd = _recsys_batch_shapes(arch, cfg, 1, spec, rules)
    elif arch == "din":
        L = cfg.seq_len
        shapes = {"hist_items": _sds((1, L), I32),
                  "hist_cates": _sds((1, L), I32),
                  "hist_mask": _sds((1, L), BOOL),
                  "cand_items": _sds((C,), I32),
                  "cand_cates": _sds((C,), I32)}
        _, _, fwd = _recsys_batch_shapes(arch, cfg, 1, spec, rules)
    elif arch == "deepfm":
        shapes = {"fields": _sds((1, cfg.n_fields), I32),
                  "cand_ids": _sds((C,), I32)}
        _, _, fwd = _recsys_batch_shapes(arch, cfg, 1, spec, rules)
    else:  # bert4rec: encode once + 1M dots
        L = cfg.seq_len
        shapes = {"items": _sds((1, L), I32), "mask": _sds((1, L), BOOL),
                  "cand_ids": _sds((C,), I32)}
        fwd = 2.0 * cfg.embed_dim       # per-candidate: one D-dim dot
    b_shard = {k: rules.sharding("candidates")
               if v.shape == (C,) else NamedSharding(mesh, P())
               for k, v in shapes.items()}
    fn = lambda p, b: retr_fn(p, b, cfg)
    args = (params_shapes, shapes)
    return CellPlan(arch, shape, mode, fn, args, (p_shard, b_shard),
                    rules.sharding("candidates"), (), rules, fwd * C)


# ===========================================================================
# ASC retrieval cells (the paper's architecture)
# ===========================================================================

def _build_retrieval(arch: str, shape: str, mesh, multi_pod: bool,
                     unroll: int = 1) -> CellPlan:
    from repro.core.search import SearchConfig
    from repro.core.types import ClusterIndex, QueryBatch
    from repro.serving import engine
    spec = RETRIEVAL_SHAPES[shape]
    icfg = get_arch(arch).config()
    rules = sh.retrieval_rules(mesh)
    B = spec["batch"]
    m, n_seg, V = icfg.m, icfg.n_seg, icfg.vocab
    dp, tp, qp = icfg.d_pad, icfg.t_pad, icfg.q_pad

    index_shapes = ClusterIndex(
        doc_tids=_sds((m, dp, tp),
                      jnp.uint16 if V < 2**16 else I32),
        doc_tw=_sds((m, dp, tp), jnp.uint8),
        doc_mask=_sds((m, dp), BOOL), doc_ids=_sds((m, dp), I32),
        doc_seg=_sds((m, dp), I32),
        doc_seg_mod=_sds((m, dp), I32),
        seg_max_stacked=_sds((m, n_seg + 1, V), jnp.uint8),
        seg_offsets=_sds((m, n_seg + 1), I32),
        sorted_upto=_sds((m,), I32),
        scale=_sds((), F32), cluster_ndocs=_sds((m,), I32),
        vocab=V, n_seg=n_seg)
    q_shapes = QueryBatch(tids=_sds((B, qp), I32), tw=_sds((B, qp), F32),
                          mask=_sds((B, qp), BOOL), vocab=V)

    ispecs = engine.index_shard_specs(index_shapes, multi_pod)
    i_shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), ispecs,
        is_leaf=lambda x: isinstance(x, P))
    q_shard = QueryBatch(
        tids=NamedSharding(mesh, P("model", None)),
        tw=NamedSharding(mesh, P("model", None)),
        mask=NamedSharding(mesh, P("model", None)), vocab=V)

    scfg = SearchConfig(k=spec["k"], mu=icfg.mu, eta=icfg.eta,
                        method="asc", group_size=icfg.group_size)
    fn = lambda idx, q: engine.distributed_retrieve(idx, q, scfg, mesh,
                                                    multi_pod=multi_pod)
    # useful work: bounds for all clusters + exhaustive scoring upper bound
    flops = B * (2.0 * m * n_seg * qp + 2.0 * icfg.n_docs * tp)
    return CellPlan(arch, shape, "retrieve", fn, (index_shapes, q_shapes),
                    (i_shard, q_shard), None, (), rules, flops,
                    notes="HLO flops reflect one while-loop group + bounds; "
                          "pruning makes useful/HLO ratio > 1 by design")


def build_cell(arch: str, shape: str, mesh, multi_pod: bool = False,
               unroll: int = 1) -> CellPlan:
    """unroll: scan-over-layers unroll factor. 1 = the production program
    (memory analysis comes from this compile); >1 = counting compile — the
    dry-run extrapolates per-layer FLOPs / collective bytes linearly from
    (u=1, u=8) since XLA cost analysis counts loop bodies once."""
    kind = arch_kind(arch)
    builder = {"lm": _build_lm, "gnn": _build_gnn, "recsys": _build_recsys,
               "retrieval": _build_retrieval}[kind]
    return builder(arch, shape, mesh, multi_pod, unroll=unroll)


def layer_count(arch: str) -> int:
    kind = arch_kind(arch)
    if kind == "lm":
        return get_arch(arch).config().n_layers
    if kind == "gnn":
        return get_arch(arch).config().n_layers
    return 1
